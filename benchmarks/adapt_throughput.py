"""A/B: serving throughput with and without interleaved on-device adaptation.

The same request stream is served twice on the reduced TinyLlama config:
once by a bare continuous-batching ``Engine`` (baseline tokens/s) and once
by a ``DeviceSession`` that runs a planner-budgeted ASI fine-tuning burst
every ``ADAPT_EVERY`` retirements.  Reported: tokens/s for both runs, the
serving-throughput retention under adaptation, adaptation steps/s, and the
session's quality/forgetting counters — the cost of learning while serving,
quantified.

Run:  PYTHONPATH=src python -m benchmarks.adapt_throughput
"""
from __future__ import annotations

import jax

from repro.configs.registry import get_config
from repro.data.synthetic import LMStream, LMStreamCfg
from repro.models import build_model
from repro.ondevice.planner import build_plan
from repro.ondevice.session import DeviceSession, SessionCfg
from repro.optim.optimizers import make_optimizer
from repro.optim.schedules import warmup_cosine
from repro.runtime.serve_loop import Engine, Request, ServeCfg
from repro.runtime.train_loop import make_train_step
from repro.telemetry import Recorder

ARCH = "tinyllama-1.1b"
N_REQUESTS, MAX_NEW, MAX_BATCH, MAX_LEN = 8, 8, 4, 64
BATCH, SEQ = 2, 16
ADAPT_EVERY, BURST, TOTAL_STEPS = 2, 1, 6
BUDGET_MB = 0.05


def _requests(n=N_REQUESTS):
    return [Request(uid=i, prompt=[1 + (i + j) % 37 for j in range(4 + i % 2)],
                    max_new_tokens=MAX_NEW) for i in range(n)]


def run(verbose: bool = True) -> dict:
    cfg = get_config(ARCH).reduced().replace(compress="asi",
                                             kernel_backend="reference")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    scfg = ServeCfg(max_batch=MAX_BATCH, max_len=MAX_LEN)
    data = LMStream(LMStreamCfg(vocab_size=cfg.vocab_size, seq_len=SEQ,
                                global_batch=BATCH, seed=0, branching=2))

    # --- baseline: serve only (warmed) --------------------------------------
    base_rec = Recorder()
    eng = Engine(api, params, scfg, telemetry=base_rec)
    eng.run(_requests(2))
    eng.run(_requests())
    base = eng.last_stats

    # --- session: serve + planner-budgeted adaptation ----------------------
    plan = build_plan(api, cfg, params, BUDGET_MB,
                      [data.batch(s) for s in range(2)],
                      batch_size=BATCH, seq_len=SEQ)
    asi_state = api.init_asi(jax.random.PRNGKey(0), rank_plan=plan.rank_plan)
    opt = make_optimizer("adamw", warmup_cosine(1e-2, 2, TOTAL_STEPS),
                         clip_norm=2.0)
    step_fn = make_train_step(lambda p, b, s: api.loss(p, b, s), opt,
                              trainable_mask=api.trainable_mask(params),
                              donate=False, kernel_backend=cfg.kernel_backend)
    sess_rec = Recorder()
    session = DeviceSession(
        api, params, step_fn, opt_state=opt.init(params),
        asi_state=asi_state, serve_cfg=scfg,
        cfg=SessionCfg(adapt_every=ADAPT_EVERY, burst_steps=BURST,
                       total_steps=TOTAL_STEPS, batch_size=BATCH,
                       seq_len=SEQ),
        probe_batch=data.batch(10_000), telemetry=sess_rec)
    # warm-up: engine prefill/step compiles AND the train-step compile (the
    # replay is seeded so one real adaptation step traces), then reset
    session.replay.add([1 + i % 37 for i in range(SEQ + 2)])
    session.engine.run(_requests(2))
    session.adapt_steps(1)
    session.reset_counters()
    # reset_counters zeroes the report, not the recorder: the telemetry
    # streams are cumulative, so take deltas from post-warm-up marks
    steps_mark = sess_rec.counter("adapt.steps").value
    loss_mark = sess_rec.hist("adapt.loss").count
    report = session.run(_requests(), drain_steps=True)
    adapt = report.serve_stats
    tele_steps = int(sess_rec.counter("adapt.steps").value - steps_mark)
    tele_losses = sess_rec.hist("adapt.loss").count - loss_mark
    # one source of truth: the report's counters must reconcile with the
    # recorder's adapt.* streams exactly
    assert tele_steps == report.steps, (tele_steps, report.steps)
    assert tele_losses == len(report.adapt_losses)

    retention = (adapt.tokens_per_s / base.tokens_per_s
                 if base.tokens_per_s else 0.0)
    steps_per_s = (report.steps / report.adapt_wall_s
                   if report.adapt_wall_s else 0.0)
    out = {
        "baseline_tok_s": base.tokens_per_s,
        "adapt_tok_s": adapt.tokens_per_s,
        "retention": retention,
        "adapt_steps_per_s": steps_per_s,
        "plan_mb": plan.planned_bytes / 2 ** 20,
        "budget_mb": BUDGET_MB,
        "quality": report.summary(),
        "telemetry": {"adapt_steps": tele_steps,
                      "bursts": int(sess_rec.counter("adapt.bursts").value),
                      "baseline_tokens":
                          int(base_rec.counter("serve.tokens").value)},
    }
    if verbose:
        print(f"serve-only        {base.tokens_per_s:7.1f} tok/s")
        print(f"serve+adapt       {adapt.tokens_per_s:7.1f} tok/s "
              f"(retention {retention:.2f}x)")
        print(f"adaptation        {report.steps} steps, "
              f"{steps_per_s:.1f} steps/s, plan {out['plan_mb']:.4f} MB "
              f"<= budget {BUDGET_MB} MB")
        print(f"loss first->last  {report.first_loss:.3f} -> "
              f"{report.last_loss:.3f}; probe drift {report.probe_drift:+.3f}")
    assert plan.within_budget
    return out


if __name__ == "__main__":
    run()
