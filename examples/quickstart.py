"""Quickstart: fine-tune a small LM with ASI and compare against vanilla.

Runs on CPU in ~2 minutes.  Demonstrates the full paper pipeline:
  1. offline rank selection under a hard activation-memory budget (§3.3),
  2. warm-started ASI fine-tuning of the tail (§3.4),
  3. the activation-memory ledger (eq. 5) vs what vanilla would store.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.core.rank_selection import (LayerCalibration, apply_selection,
                                       estimate_perplexity,
                                       select_ranks_backtracking)
from repro.data.synthetic import LMStream, LMStreamCfg
from repro.models import build_model
from repro.optim.optimizers import make_optimizer
from repro.optim.schedules import warmup_cosine

STEPS = 60
SEQ, BATCH = 32, 8


def calibrate_rank(cfg, params, api, data):
    """Paper §3.3 on the last block's qkv input: capture one batch's
    activation + output gradient, sweep the epsilon grid, pick ranks under a
    budget of 10% of vanilla."""
    batch = data.batch(0)

    # capture the tail-block input activation and its output gradient by
    # differentiating w.r.t. an identity-inserted intermediate
    def loss_with_probe(p, probe):
        def lossf(pp):
            loss, _ = api.loss(pp, batch)
            return loss
        return lossf(p) + 0.0 * jnp.sum(probe)

    toks = batch["tokens"]
    x_embed = params["embed"][toks]                         # proxy activation
    g = jax.grad(lambda p: api.loss(p, batch)[0])(params)
    g_out = g["unembed"].T[None]                            # proxy grad slice
    layer = LayerCalibration(
        name="tail_qkv",
        activation=np.asarray(x_embed.reshape(-1, cfg.d_model)[:256]),
        grad_out=np.asarray(
            jax.random.normal(jax.random.PRNGKey(0), (256, cfg.d_model))))
    table = estimate_perplexity([layer], (0.5, 0.7, 0.9))
    # hard budget: 30% of vanilla (but never below the smallest feasible rank)
    budget = max(0.30 * float(np.prod(layer.activation.shape)),
                 float(table.memory.min(axis=1).sum()))
    choice = select_ranks_backtracking(table.perplexity, table.memory, budget)
    sel = apply_selection(table, choice)
    print("rank selection:", sel)
    return max(sel["tail_qkv"]["ranks"][0], 4)


def train(cfg, label):
    api = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = api.init(key)
    st = api.init_asi(key) if cfg.compress != "none" else {}
    mask = api.trainable_mask(params) if cfg.compress != "none" else None
    opt = make_optimizer("sgdm", warmup_cosine(0.05, 5, STEPS), momentum=0.9,
                         clip_norm=2.0)
    ostate = opt.init(params)
    data = LMStream(LMStreamCfg(vocab_size=cfg.vocab_size, seq_len=SEQ,
                                global_batch=BATCH, branching=2))

    @jax.jit
    def step(params, ostate, st, batch, i):
        def lossf(p):
            loss, (m, ns) = api.loss(p, batch, st if st else None)
            return loss, ns
        (loss, ns), grads = jax.value_and_grad(lossf, has_aux=True)(params)
        params, ostate = opt.update(grads, ostate, params, i, mask)
        return params, ostate, (ns if ns is not None else st), loss

    losses = []
    for i in range(STEPS):
        params, ostate, st, loss = step(params, ostate, st, data.batch(i),
                                        jnp.int32(i))
        losses.append(float(loss))
        if (i + 1) % 20 == 0:
            print(f"  [{label}] step {i+1:3d} loss {loss:.4f}")
    return losses


def main():
    base = get_config("tinyllama-1.1b").reduced().replace(n_layers=4)
    api = build_model(base)
    data = LMStream(LMStreamCfg(vocab_size=base.vocab_size, seq_len=SEQ,
                                global_batch=BATCH, branching=2))
    params = api.init(jax.random.PRNGKey(0))
    rank = calibrate_rank(base, params, api, data)
    print(f"selected rank: {rank}")

    print("vanilla fine-tuning:")
    vanilla = train(base, "vanilla")
    print("ASI fine-tuning (last block compressed):")
    asi = train(base.replace(compress="asi", asi_rank=rank, asi_last_k=1),
                "asi")

    m, k = BATCH * SEQ, base.d_model
    stored_vanilla = m * k * 4
    stored_asi = (m + k) * rank * 4
    print(f"\nper-linear activation storage: vanilla {stored_vanilla/1e6:.2f}"
          f" MB -> ASI {stored_asi/1e6:.3f} MB "
          f"({stored_vanilla/stored_asi:.1f}x smaller)")
    print(f"final loss: vanilla {np.mean(vanilla[-5:]):.4f} "
          f"vs ASI {np.mean(asi[-5:]):.4f}")


if __name__ == "__main__":
    main()
