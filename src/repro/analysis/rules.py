"""Import every rule module so its ``@rule`` registrations land in
``repro.analysis.core.RULES``.  The CLI and ``scripts/repro_lint.py``
import this module once before calling ``run_lint``; tests can import it
too and then select individual rules."""
from __future__ import annotations

from repro.analysis import graph  # noqa: F401  (graph-plane rule family)
from repro.analysis import jit_purity  # noqa: F401
from repro.analysis import pallas_contract  # noqa: F401
from repro.analysis import partition_coverage  # noqa: F401
from repro.analysis import residual_contract  # noqa: F401
from repro.analysis import shim_contract  # noqa: F401
from repro.analysis import telemetry_contract  # noqa: F401
from repro.analysis.core import RULES

__all__ = ["RULES"]
